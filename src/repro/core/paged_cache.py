"""Paged KV-cache management (WebLLM's WASM sequence manager, in Python).

``PageManager`` is the pure bookkeeping side: a free list of physical
pages, per-sequence page tables, allocate-on-append, and preemption
support (free a whole sequence).  ``PagedKVState`` owns the jax-side page
pools for every attention layer of a model and performs token writes +
paged-attention reads (via the Pallas kernel on TPU / interpret on CPU).

Pages are reference-counted so they can be shared between live sequences
and the prefix cache (``repro.core.prefix_cache``): a page returns to the
free list only when its last reference drops.  ``share_pages`` adopts
already-filled pages into a sequence (+1 ref each) and ``fork_page``
implements copy-on-write of a partially filled tail page — the sequence
gets a private physical page it may write into, while the shared source
page stays immutable.

Non-attention state (SSM/RWKV/conv, MLA latents) is slot-based: O(1) per
sequence, managed by the same slot ids.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


class OutOfPages(Exception):
    pass


@dataclass
class SeqAlloc:
    seq_id: int
    slot: int                      # dense batch slot / state row
    pages: List[int] = field(default_factory=list)
    length: int = 0                # tokens currently stored


class PageManager:
    """Free-list page allocator + refcounted per-sequence page tables."""

    # lint (repro.analysis pass 1): allocator state is confined to the
    # engine loop thread; ``stats``/``num_free_pages`` are the len-only
    # probes other threads may call.
    _THREAD_CONFINED = ("free_pages", "free_slots", "seqs", "ref",
                        "_next_id", "n_shared", "n_cow_forks")
    _CROSS_THREAD = ("stats", "num_free_pages")

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 pages_per_seq: int):
        self.page_size = page_size
        self.num_pages = num_pages
        self.pages_per_seq = pages_per_seq
        self.free_pages: List[int] = list(range(num_pages))
        self.free_slots: List[int] = list(range(max_slots))
        self.seqs: Dict[int, SeqAlloc] = {}
        self.ref: Dict[int, int] = {}          # physical page -> refcount
        self._next_id = 0
        self.n_shared = 0                      # pages adopted zero-copy
        self.n_cow_forks = 0                   # tail pages forked CoW
        # hooks installed by the prefix cache: reclaim(n) tries to evict
        # cached pages back to the free list; evictable() reports how many
        # it could free on demand (for admission accounting).
        self.reclaim: Optional[Callable[[int], int]] = None
        self.evictable: Optional[Callable[[], int]] = None

    # -- refcounting --------------------------------------------------
    def ref_page(self, page: int):
        self.ref[page] = self.ref.get(page, 0) + 1

    def deref_page(self, page: int):
        n = self.ref.get(page, 0) - 1
        if n > 0:
            self.ref[page] = n
        else:
            self.ref.pop(page, None)
            self.free_pages.append(page)

    def _alloc_page(self) -> int:
        if not self.free_pages and self.reclaim is not None:
            self.reclaim(1)
        if not self.free_pages:
            raise OutOfPages("page pool exhausted")
        p = self.free_pages.pop()
        self.ref[p] = 1
        return p

    def require_pages(self, n: int):
        """Raise OutOfPages *before* any state mutation unless ``n`` pages
        can be produced (free list + prefix-cache eviction)."""
        if len(self.free_pages) >= n:
            return
        if self.reclaim is not None:
            self.reclaim(n - len(self.free_pages))
        if len(self.free_pages) < n:
            raise OutOfPages(
                f"need {n} pages, have {len(self.free_pages)}")

    # -- lifecycle ----------------------------------------------------
    def new_seq(self) -> SeqAlloc:
        if not self.free_slots:
            raise OutOfPages("no free slots")
        sid = self._next_id
        self._next_id += 1
        alloc = SeqAlloc(seq_id=sid, slot=self.free_slots.pop())
        self.seqs[sid] = alloc
        return alloc

    def free_seq(self, seq_id: int):
        alloc = self.seqs.pop(seq_id)
        for p in alloc.pages:
            self.deref_page(p)
        self.free_slots.append(alloc.slot)

    # -- sharing / copy-on-write ----------------------------------------
    def share_pages(self, seq_id: int, pages: List[int], n_tokens: int):
        """Adopt already-filled ``pages`` (e.g. a cached prefix) into a
        sequence: +1 ref each, no data movement.  The adopted pages must
        be full and must precede any page the sequence will write."""
        alloc = self.seqs[seq_id]
        if len(alloc.pages) + len(pages) > self.pages_per_seq:
            raise OutOfPages("shared prefix exceeds pages_per_seq")
        for p in pages:
            self.ref_page(p)
            alloc.pages.append(p)
        alloc.length += n_tokens
        self.n_shared += len(pages)

    def fork_page(self, seq_id: int, n_tokens: int) -> int:
        """Copy-on-write bookkeeping for a partially filled tail page:
        allocate a private destination page, append it to the sequence,
        and account ``n_tokens`` adopted tokens.  The caller is
        responsible for copying the KV payload src -> returned page."""
        alloc = self.seqs[seq_id]
        if len(alloc.pages) + 1 > self.pages_per_seq:
            raise OutOfPages("fork exceeds pages_per_seq")
        dst = self._alloc_page()
        alloc.pages.append(dst)
        alloc.length += n_tokens
        self.n_cow_forks += 1
        return dst

    # -- growth ---------------------------------------------------------
    def ensure_capacity(self, seq_id: int, new_length: int):
        """Allocate pages so the sequence can hold ``new_length`` tokens."""
        alloc = self.seqs[seq_id]
        need = -(-new_length // self.page_size)          # ceil
        if need > self.pages_per_seq:
            raise OutOfPages(
                f"sequence needs {need} pages > pages_per_seq "
                f"{self.pages_per_seq}")
        while len(alloc.pages) < need:
            alloc.pages.append(self._alloc_page())

    def append_tokens(self, seq_id: int, n: int = 1):
        alloc = self.seqs[seq_id]
        self.ensure_capacity(seq_id, alloc.length + n)
        alloc.length += n

    def rewind_tokens(self, seq_id: int, n: int = 1):
        """Roll the sequence's cursor back ``n`` tokens and drop any
        trailing pages the rolled-back tokens had forced into existence
        (lag-1: the pipelined engine's finish rewind; lag-k: the
        rejected tail of a speculative verify window).  Only pages
        *beyond* the new length are released — appended tokens never
        land in shared pages (``append_tokens`` allocates private
        pages; adoption shares only FULL pages and ``fork`` copies the
        partial tail), so even a rewind that crosses page boundaries,
        follows a CoW fork, or sits next to prefix-cache-published
        pages can only pop pages this sequence privately owns."""
        alloc = self.seqs[seq_id]
        assert 0 <= n <= alloc.length, (seq_id, n, alloc.length)
        alloc.length -= n
        need = -(-alloc.length // self.page_size)
        while len(alloc.pages) > need:
            self.deref_page(alloc.pages.pop())

    # -- views -----------------------------------------------------------
    def page_table(self, seq_ids: List[int]) -> np.ndarray:
        """[len(seq_ids), pages_per_seq] int32 (0-padded)."""
        out = np.zeros((len(seq_ids), self.pages_per_seq), np.int32)
        for i, sid in enumerate(seq_ids):
            pages = self.seqs[sid].pages
            out[i, :len(pages)] = pages
        return out

    def context_lens(self, seq_ids: List[int]) -> np.ndarray:
        return np.array([self.seqs[s].length for s in seq_ids], np.int32)

    def slots(self, seq_ids: List[int]) -> np.ndarray:
        return np.array([self.seqs[s].slot for s in seq_ids], np.int32)

    @property
    def num_free_pages(self) -> int:
        return len(self.free_pages)

    @property
    def available_pages(self) -> int:
        """Free pages plus pages the prefix cache could evict on demand."""
        extra = self.evictable() if self.evictable is not None else 0
        return len(self.free_pages) + extra

    def stats(self) -> dict:
        return {"free_pages": len(self.free_pages),
                "used_pages": self.num_pages - len(self.free_pages),
                "active_seqs": len(self.seqs),
                "shared_pages": self.n_shared,
                "cow_forks": self.n_cow_forks}
