"""Docs cross-check — ``docs/ARCHITECTURE.md`` §"Threading model and
lock hierarchy" cannot drift from the code annotations.

Checks (all emitted as findings so the CI gate sees them):

* ``doc-section-missing`` — the threading section heading is absent.
* ``doc-lock-missing`` — a lock declared in a ``_GUARDED_BY`` registry
  is never mentioned as ``ClassName.<lock>`` in the docs.
* ``doc-order-drift`` — the documented acquisition-order line (a line
  containing "acquisition order:") does not list exactly
  :data:`repro.analysis.hierarchy.LOCK_ORDER`.
* ``doc-thread-missing`` — a named thread population (the constant
  prefix of every ``threading.Thread(name=...)``) is undocumented.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List, Sequence, Set, Tuple

from repro.analysis import hierarchy
from repro.analysis.common import Finding, Module, build_class_map

_SECTION_RE = re.compile(r"^#+.*Threading model", re.IGNORECASE | re.MULTILINE)
_ORDER_LINE_RE = re.compile(r"acquisition order:(.*)$",
                            re.IGNORECASE | re.MULTILINE)
_LOCK_TOKEN_RE = re.compile(r"(\w+\._\w+)")


def _declared_locks(modules: Sequence[Module]) -> Set[str]:
    out: Set[str] = set()
    for cls in build_class_map(modules).values():
        for lock in cls.guarded_by:
            out.add(f"{cls.name}.{lock}")
        for lock in cls.guarded_fields:
            out.add(f"{cls.name}.{lock}")
    return out


def _thread_name_prefixes(modules: Sequence[Module]) -> Set[Tuple[str, str, int]]:
    """(prefix, rel, line) for every ``threading.Thread(name=...)``: the
    whole literal, or the leading constant of an f-string."""
    out: Set[Tuple[str, str, int]] = set()
    for mod in modules:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and ((isinstance(node.func, ast.Attribute)
                          and node.func.attr == "Thread")
                         or (isinstance(node.func, ast.Name)
                             and node.func.id == "Thread"))):
                continue
            for kw in node.keywords:
                if kw.arg != "name":
                    continue
                v = kw.value
                prefix = None
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    prefix = v.value
                elif (isinstance(v, ast.JoinedStr) and v.values
                      and isinstance(v.values[0], ast.Constant)):
                    prefix = str(v.values[0].value)
                if prefix:
                    out.add((prefix.rstrip("-[{"), mod.rel, node.lineno))
    return out


def run(modules: Sequence[Module], doc_path: Path,
        rel: str = "docs/ARCHITECTURE.md") -> List[Finding]:
    findings: List[Finding] = []
    if not doc_path.exists():
        return [Finding(rule="doc-section-missing", path=rel, line=1,
                        scope="<doc>",
                        message="docs/ARCHITECTURE.md not found — the "
                                "threading model must be documented")]
    text = doc_path.read_text()
    if not _SECTION_RE.search(text):
        findings.append(Finding(
            rule="doc-section-missing", path=rel, line=1, scope="<doc>",
            message='no "Threading model" section heading in '
                    'docs/ARCHITECTURE.md'))
        return findings

    for lock in sorted(_declared_locks(modules)):
        if lock not in text:
            findings.append(Finding(
                rule="doc-lock-missing", path=rel, line=1, scope="<doc>",
                message=f"declared lock {lock} is not documented in the "
                        f"threading section"))

    m = _ORDER_LINE_RE.search(text)
    if not m:
        findings.append(Finding(
            rule="doc-order-drift", path=rel, line=1, scope="<doc>",
            message='no "acquisition order:" line documenting the lock '
                    'hierarchy'))
    else:
        doc_order = tuple(_LOCK_TOKEN_RE.findall(m.group(1)))
        if doc_order != hierarchy.LOCK_ORDER:
            findings.append(Finding(
                rule="doc-order-drift", path=rel, line=1, scope="<doc>",
                message=f"documented lock order {' -> '.join(doc_order)} "
                        f"!= declared "
                        f"{' -> '.join(hierarchy.LOCK_ORDER)}"))

    for prefix, code_rel, line in sorted(_thread_name_prefixes(modules)):
        if prefix not in text:
            findings.append(Finding(
                rule="doc-thread-missing", path=code_rel, line=line,
                scope="<doc>",
                message=f'thread population "{prefix}" is not documented '
                        f'in the threading section'))
    return findings
