"""The declared lock-acquisition hierarchy of the serving core, plus the
attribute-name -> class typing hints the lock pass uses to resolve
cross-class calls (Python has no static types; the serving core's
receiver names are stable enough to declare here).

``LOCK_ORDER`` lists locks OUTERMOST FIRST: a thread holding a lock may
only acquire locks that appear LATER in the order.  Acquiring an
earlier lock — or re-acquiring the same non-reentrant lock — while a
later one is held is a deadlock report.

This tuple is the single source of truth: the static lock pass enforces
it, :mod:`repro.analysis.docs_check` asserts ``docs/ARCHITECTURE.md``
documents exactly this order, and ``tests/test_thread_safety.py``'s
runtime recorder asserts observed acquisition order is consistent with
it.
"""
from __future__ import annotations

#: Outermost -> innermost.  router above worker above engine: the router
#: briefly takes its own lock to pick a replica, then calls into the
#: frontend handle (worker lock), which posts to the backend engine
#: (engine lock).  No code path may climb back up while holding a lower
#: lock.
LOCK_ORDER = (
    "RouterEngine._lock",
    "ServiceWorkerMLCEngine._lock",
    "MLCEngine._lock",
)

#: Receiver-name -> class-name typing hints for call resolution in the
#: lock pass: ``self.engine.abort(...)`` / ``front.stats(...)`` resolve
#: through this table.  Names not listed stay unresolved (no findings).
ATTR_TYPES = {
    "engine": "MLCEngine",
    "backend": "MLCEngine",
    "front": "ServiceWorkerMLCEngine",
    "worker": "BackendWorker",
    "scheduler": "Scheduler",
    "prefix_cache": "PrefixCache",
    "pm": "PageManager",
    "router": "RouterEngine",
}
