"""Pass 2 — donation safety for ``jax.jit(..., donate_argnums=...)``.

When a buffer is donated to a jitted call its storage is reused for the
outputs: every later read of the old reference is a use-after-donate
(XLA may error, or silently read clobbered memory on some backends).
The safe idiom in this tree is *rebinding in the same statement*::

    logits, self.k_pages, self.v_pages = self._step(
        self.params, self.k_pages, self.v_pages, ...)

Rules
-----
``donate-no-rebind``
    An argument in a donated position is a ``self.X`` attribute or a
    local name that is NOT rebound from the result in the same
    assignment statement.
``donate-alias-read``
    A local alias of a donated buffer (``kp = self.k_pages`` earlier in
    the function) is read after the donating call.
``donate-params``
    Model parameters (``self.params`` / a name containing "params")
    appear in a donated position — donating weights destroys the model
    for every later step.

Registry discovery (purely syntactic):
* ``self.X = jax.jit(fn, donate_argnums=(...))`` or
  ``X = jax.jit(fn, donate_argnums=...)`` -> calls to ``self.X(...)`` /
  ``X(...)`` are donating call sites;
* ``@jax.jit(... donate_argnums ...)`` /
  ``@partial(jax.jit, donate_argnums=...)`` decorated functions.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import (Finding, Module, flatten_targets,
                                   self_attr)


def _is_jax_jit(func: ast.AST) -> bool:
    return ((isinstance(func, ast.Attribute) and func.attr == "jit")
            or (isinstance(func, ast.Name) and func.id == "jit"))


def _donated_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """donate_argnums of a ``jax.jit`` / ``partial(jax.jit, ...)`` call,
    or None if the call doesn't donate."""
    if not isinstance(call, ast.Call):
        return None
    is_jit = _is_jax_jit(call.func)
    is_partial_jit = (isinstance(call.func, ast.Name)
                      and call.func.id == "partial" and call.args
                      and _is_jax_jit(call.args[0]))
    if not (is_jit or is_partial_jit):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            try:
                val = ast.literal_eval(kw.value)
            except ValueError:
                return None
            if isinstance(val, int):
                return (val,)
            return tuple(int(v) for v in val)
    return None


def _token(node: ast.AST) -> Optional[Tuple[str, str]]:
    """A trackable buffer reference: ("self", attr) or ("local", name)."""
    name = self_attr(node)
    if name is not None:
        return ("self", name)
    if isinstance(node, ast.Name):
        return ("local", node.id)
    return None


def _fmt(tok: Tuple[str, str]) -> str:
    return f"self.{tok[1]}" if tok[0] == "self" else tok[1]


class _FuncScanner:
    """Linear scan of one function body in source order."""

    def __init__(self, registry: Dict[str, Tuple[int, ...]],
                 rel: str, scope: str, findings: List[Finding]):
        self.registry = registry
        self.rel = rel
        self.scope = scope
        self.findings = findings
        #: alias name -> token it aliases (one level, lexical)
        self.aliases: Dict[str, Tuple[str, str]] = {}
        #: tokens whose storage has been donated (pending rebinding)
        self.dead: Dict[Tuple[str, str], int] = {}   # token -> donate line

    def _emit(self, rule: str, line: int, message: str):
        self.findings.append(Finding(rule=rule, path=self.rel, line=line,
                                     scope=self.scope, message=message))

    def _callee_name(self, call: ast.Call) -> Optional[str]:
        name = self_attr(call.func)
        if name is not None:
            return name
        if isinstance(call.func, ast.Name):
            return call.func.id
        return None

    def _find_donating_call(self, node: ast.AST) -> Optional[ast.Call]:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = self._callee_name(sub)
                if name is not None and name in self.registry:
                    return sub
        return None

    def _equiv(self, tok: Tuple[str, str]) -> Set[Tuple[str, str]]:
        """The donated token plus every lexical alias of the same buffer."""
        out = {tok}
        if tok[0] == "local" and tok[1] in self.aliases:
            out.add(self.aliases[tok[1]])
        for name, target in self.aliases.items():
            if target in out:
                out.add(("local", name))
        return out

    def _check_reads(self, node: ast.AST):
        """Flag reads of donated-and-not-rebound tokens."""
        if not self.dead:
            return
        for sub in ast.walk(node):
            tok = _token(sub)
            if tok in self.dead and isinstance(getattr(sub, "ctx", None),
                                               ast.Load):
                self._emit("donate-alias-read", sub.lineno,
                           f"read of {_fmt(tok)} after its buffer was "
                           f"donated (donated as a jit argument; rebind "
                           f"from the call result first)")
                del self.dead[tok]      # one report per token

    def _handle_call(self, call: ast.Call, targets: List[ast.AST],
                     line: int):
        name = self._callee_name(call)
        positions = self.registry.get(name or "")
        if positions is None:
            return
        bound: Set[Tuple[str, str]] = set()
        for t in targets:
            tok = _token(t)
            if tok is not None:
                bound.add(tok)
        for pos in positions:
            if pos >= len(call.args):
                continue
            arg = call.args[pos]
            tok = _token(arg)
            if tok is None:
                continue
            if "params" in tok[1]:
                self._emit("donate-params", line,
                           f"{_fmt(tok)} is passed in donated position "
                           f"{pos} of {name}() — donating model weights "
                           f"destroys them for every later call")
                continue
            if tok in bound:
                # rebound in the same statement: aliases of the OLD
                # buffer are still dead
                for eq in self._equiv(tok) - {tok}:
                    if eq not in bound:
                        self.dead[eq] = line
            else:
                self._emit("donate-no-rebind", line,
                           f"{_fmt(tok)} is donated to {name}() but not "
                           f"rebound from the result in the same "
                           f"statement — later reads are "
                           f"use-after-donate")
                for eq in self._equiv(tok):
                    if eq not in bound:
                        self.dead[eq] = line

    def scan_body(self, body: Sequence[ast.stmt]):
        for stmt in body:
            # donating calls are only recognized in SIMPLE statements
            # (Assign/Expr); compound statements recurse below so each
            # inner statement is judged exactly once
            call = None
            if isinstance(stmt, (ast.Assign, ast.Expr)):
                call = self._find_donating_call(stmt)
            if call is None:
                # reads of already-donated tokens: whole statement for
                # simple statements, header expressions only for
                # compound ones (their bodies recurse below, after any
                # revival rebinds inside them are seen in order)
                if isinstance(stmt, ast.If) or isinstance(stmt, ast.While):
                    self._check_reads(stmt.test)
                elif isinstance(stmt, ast.For):
                    self._check_reads(stmt.iter)
                elif isinstance(stmt, ast.With):
                    for item in stmt.items:
                        self._check_reads(item.context_expr)
                elif isinstance(stmt, (ast.Try, ast.FunctionDef,
                                       ast.AsyncFunctionDef, ast.ClassDef)):
                    pass
                else:
                    self._check_reads(stmt)
            if isinstance(stmt, ast.Assign):
                targets: List[ast.AST] = []
                for t in stmt.targets:
                    targets.extend(flatten_targets(t))
                if call is not None:
                    self._handle_call(call, targets, stmt.lineno)
                # rebinding a dead token revives it; simple aliases
                # (name = self.X) are tracked for later donation checks
                for t in targets:
                    tok = _token(t)
                    if tok is None:
                        continue
                    self.dead.pop(tok, None)
                    if tok[0] == "local":
                        src = _token(stmt.value)
                        if src is not None and len(targets) == 1:
                            self.aliases[tok[1]] = src
                        else:
                            self.aliases.pop(tok[1], None)
            elif call is not None:
                # donating call whose result is discarded
                self._handle_call(call, [], stmt.lineno)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue        # nested defs get their own scanner
            # recurse into nested blocks in source order
            for attr in ("body", "orelse", "finalbody"):
                inner = getattr(stmt, attr, None)
                if inner:
                    self.scan_body(inner)
            for h in getattr(stmt, "handlers", []) or []:
                self.scan_body(h.body)


def run(modules: Sequence[Module]) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        # 1. registry of donating callables in this module
        registry: Dict[str, Tuple[int, ...]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                pos = _donated_positions(node.value)
                if pos is None:
                    continue
                for t in node.targets:
                    tok = _token(t)
                    if tok is not None:
                        registry[tok[1]] = pos
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call):
                        pos = _donated_positions(dec)
                        if pos is not None:
                            registry[node.name] = pos
        if not registry:
            continue
        # 2. scan every function for donating call sites
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = node.name
                parent_cls = next(
                    (c.name for c in ast.walk(mod.tree)
                     if isinstance(c, ast.ClassDef) and node in c.body),
                    None)
                if parent_cls:
                    scope = f"{parent_cls}.{node.name}"
                sc = _FuncScanner(registry, mod.rel, scope, findings)
                sc.scan_body(node.body)
    return findings
