"""``python -m repro.analysis.lint`` — the four-pass static analyzer
over the threaded serving core, with a findings baseline gate.

Default mode (no positional paths) analyzes ``src/repro/core`` +
``src/repro/kernels`` under ``--root`` (the repo root by default) and
cross-checks ``docs/ARCHITECTURE.md``.  Explicit positional paths
analyze just those files (no docs check) — that is how the self-test
corpus under ``tests/lint_corpus/`` is linted.

Exit status: 0 iff no unsuppressed findings.  ``--baseline`` suppresses
findings whose line-number-free key appears in the committed baseline
file (``src/repro/analysis/baseline.json``) — NEW findings still fail,
which is the CI contract ``scripts/check_tree.sh`` enforces.  Stale
baseline entries are reported (stderr) but do not fail the gate.

``--json PATH`` writes the machine-readable report::

    {"findings": [{"rule", "path", "line", "scope", "message", "key"}],
     "counts": {rule: n}, "waived": n, "baseline_suppressed": n,
     "baseline_stale": [...], "elapsed_s": t}
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import Counter
from pathlib import Path
from typing import List

from repro.analysis import docs_check, donation, locks, protocol, threads
from repro.analysis.common import Finding, Module, load_module

_PKG_DIR = Path(__file__).resolve().parent
DEFAULT_BASELINE = _PKG_DIR / "baseline.json"
#: analyzed by default, relative to --root
DEFAULT_TARGETS = ("src/repro/core", "src/repro/kernels")


def _collect_files(root: Path, paths: List[str]) -> List[Path]:
    if paths:
        out = []
        for p in paths:
            pp = Path(p)
            if pp.is_dir():
                out.extend(sorted(pp.glob("*.py")))
            else:
                out.append(pp)
        return out
    files: List[Path] = []
    for target in DEFAULT_TARGETS:
        d = root / target
        if d.is_dir():
            files.extend(sorted(d.glob("*.py")))
    return files


def run_passes(modules: List[Module], with_docs: bool,
               root: Path) -> List[Finding]:
    findings: List[Finding] = []
    findings.extend(locks.run(modules))
    findings.extend(donation.run(modules))
    findings.extend(protocol.run(modules))
    findings.extend(threads.run(modules))
    if with_docs:
        findings.extend(docs_check.run(modules,
                                       root / "docs" / "ARCHITECTURE.md"))
    return findings


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static analysis of the threaded serving core")
    ap.add_argument("paths", nargs="*",
                    help="explicit files/dirs (default: the serving core "
                         "under --root, plus the docs cross-check)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: inferred from this package)")
    ap.add_argument("--baseline", action="store_true",
                    help="suppress findings present in the baseline file; "
                         "only NEW findings fail")
    ap.add_argument("--baseline-file", default=str(DEFAULT_BASELINE))
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline file from current findings")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.monotonic()
    root = Path(args.root) if args.root else _PKG_DIR.parents[2]
    files = _collect_files(root, args.paths)
    if not files:
        print(f"lint: no python files found under {root}", file=sys.stderr)
        return 2
    modules = [load_module(f, root) for f in files]
    findings = run_passes(modules, with_docs=not args.paths, root=root)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))

    # line-comment waivers (lint: ignore[rule])
    by_rel = {m.rel: m for m in modules}
    kept: List[Finding] = []
    waived = 0
    for f in findings:
        mod = by_rel.get(f.path)
        if mod is not None and f.rule in mod.waived_rules(f.line):
            waived += 1
        else:
            kept.append(f)
    findings = kept

    baseline_path = Path(args.baseline_file)
    if args.write_baseline:
        baseline_path.write_text(json.dumps(
            {"keys": sorted(f.key for f in findings)}, indent=1) + "\n")
        print(f"lint: wrote {len(findings)} baseline keys to "
              f"{baseline_path}")
        return 0

    suppressed = 0
    stale: List[str] = []
    if args.baseline:
        keys = set()
        if baseline_path.exists():
            keys = set(json.loads(baseline_path.read_text())
                       .get("keys", []))
        current = {f.key for f in findings}
        stale = sorted(keys - current)
        kept = []
        for f in findings:
            if f.key in keys:
                suppressed += 1
            else:
                kept.append(f)
        findings = kept

    elapsed = time.monotonic() - t0
    if not args.quiet:
        for f in findings:
            print(f.render())
        if stale:
            print(f"lint: {len(stale)} stale baseline entries (fixed "
                  f"findings still listed in {baseline_path.name}); "
                  f"refresh with --write-baseline", file=sys.stderr)
        summary = (f"lint: {len(findings)} findings"
                   f" ({waived} waived, {suppressed} baselined)"
                   f" across {len(files)} files in {elapsed:.2f}s")
        print(summary, file=sys.stderr)
    if args.json_out:
        Path(args.json_out).write_text(json.dumps({
            "findings": [{"rule": f.rule, "path": f.path, "line": f.line,
                          "scope": f.scope, "message": f.message,
                          "key": f.key} for f in findings],
            "counts": dict(Counter(f.rule for f in findings)),
            "waived": waived,
            "baseline_suppressed": suppressed,
            "baseline_stale": stale,
            "elapsed_s": round(elapsed, 3),
        }, indent=1) + "\n")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
