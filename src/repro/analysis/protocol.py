"""Pass 3 — worker JSON-boundary exhaustiveness.

The frontend/backend split speaks ONLY ``{"kind": ...}`` JSON messages
over the port (``core/worker.py``).  A kind emitted on one side with no
handler branch on the peer side is a silent message drop (the bug class
behind hung frontends); a handler branch for a kind nobody emits is
protocol drift.  Typed crash errors cross the boundary as an ``etype``
tag that must map back to a real exception class.

Sides: emits via ``self._post(...)`` belong to the WORKER side, emits
via ``self._send(...)`` to the CLIENT side (the method names are the
convention; :class:`ProtocolConfig` can re-declare which classes sit on
which side).  Handler branches are comparisons/membership tests of a
kind expression (``msg["kind"]``, ``msg.get("kind")``, or a variable
named ``kind``) against string literals.

Rules
-----
``protocol-unhandled``  — kind emitted, no peer handler branch.
``protocol-stale-handler`` — handler branch for a kind never emitted by
the peer (skipped when the peer side emits no literals at all).
``etype-unresolvable`` — an ``_ETYPES`` registry key/value (or a literal
compared against ``msg.get("etype")``) that does not name a class
defined/imported at module top level, or a key that mismatches its
class.
``etype-never-sent`` — the module compares/maps ``etype`` tags but no
emitted ``"error"``/``"crash"`` message literal carries an ``"etype"``
key.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, Module, const_str

#: emit method name -> side of the class that CALLS it
EMIT_SIDES = {"_post": "worker", "_send": "client"}


@dataclass
class ProtocolConfig:
    #: class name -> side ("worker" | "client")
    sides: Dict[str, str] = field(default_factory=lambda: {
        "BackendWorker": "worker",
        "ServiceWorkerMLCEngine": "client",
    })


def _is_kind_expr(node: ast.AST) -> bool:
    """msg["kind"] / msg.get("kind") / a variable named like ``kind``."""
    if isinstance(node, ast.Subscript):
        sl = node.slice
        if isinstance(sl, ast.Index):              # py<3.9 compat
            sl = sl.value
        return const_str(sl) == "kind"
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr == "get" and node.args):
        return const_str(node.args[0]) == "kind"
    if isinstance(node, ast.Name):
        return node.id == "kind" or node.id.endswith("_kind")
    return False


def _is_etype_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Subscript):
        sl = node.slice
        if isinstance(sl, ast.Index):
            sl = sl.value
        return const_str(sl) == "etype"
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr == "get" and node.args):
        return const_str(node.args[0]) == "etype"
    return isinstance(node, ast.Name) and node.id == "etype"


def _literals(node: ast.AST) -> List[str]:
    """String literals in a comparator: "x" or ("x", "y")."""
    s = const_str(node)
    if s is not None:
        return [s]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out = []
        for elt in node.elts:
            s = const_str(elt)
            if s is not None:
                out.append(s)
        return out
    return []


def _dict_entry(d: ast.Dict, key: str) -> Optional[ast.AST]:
    for k, v in zip(d.keys, d.values):
        if k is not None and const_str(k) == key:
            return v
    return None


def run(modules: Sequence[Module],
        config: Optional[ProtocolConfig] = None) -> List[Finding]:
    cfg = config or ProtocolConfig()
    findings: List[Finding] = []
    for mod in modules:
        classes = [n for n in mod.tree.body if isinstance(n, ast.ClassDef)]
        relevant = [c for c in classes if c.name in cfg.sides]
        if not relevant:
            continue
        #: side -> {kind -> first emit (scope, line)}
        emitted: Dict[str, Dict[str, Tuple[str, int]]] = {"worker": {},
                                                          "client": {}}
        handled: Dict[str, Dict[str, Tuple[str, int]]] = {"worker": {},
                                                          "client": {}}
        etype_emitted = False
        etype_refs: List[Tuple[str, str, int]] = []   # (name, scope, line)
        top_names = _module_names(mod.tree)

        for cls in relevant:
            side = cfg.sides[cls.name]
            for meth in [n for n in cls.body
                         if isinstance(n, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))]:
                scope = f"{cls.name}.{meth.name}"
                for node in ast.walk(meth):
                    # emits: self._post({...}) / self._send({...})
                    if (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Attribute)
                            and node.func.attr in EMIT_SIDES
                            and node.args
                            and isinstance(node.args[0], ast.Dict)):
                        d = node.args[0]
                        kv = _dict_entry(d, "kind")
                        kind = const_str(kv) if kv is not None else None
                        if kind is not None:
                            emit_side = EMIT_SIDES[node.func.attr]
                            emitted[emit_side].setdefault(
                                kind, (scope, node.lineno))
                            if _dict_entry(d, "etype") is not None:
                                etype_emitted = True
                    # handlers: comparisons / membership on a kind expr
                    if isinstance(node, ast.Compare):
                        sides_of_cmp = [node.left] + list(node.comparators)
                        if any(_is_kind_expr(s) for s in sides_of_cmp):
                            for s in sides_of_cmp:
                                for lit in _literals(s):
                                    handled[side].setdefault(
                                        lit, (scope, node.lineno))
                        if any(_is_etype_expr(s) for s in sides_of_cmp):
                            for s in sides_of_cmp:
                                for lit in _literals(s):
                                    etype_refs.append((lit, scope,
                                                       node.lineno))

        # the _ETYPES registry: module-level dict mapping tag -> class
        for node in mod.tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and "_ETYPES" in node.targets[0].id
                    and isinstance(node.value, ast.Dict)):
                for k, v in zip(node.value.keys, node.value.values):
                    tag = const_str(k) if k is not None else None
                    if tag is None:
                        continue
                    etype_refs.append((tag, "<module>", node.lineno))
                    vname = v.id if isinstance(v, ast.Name) else None
                    if vname != tag:
                        findings.append(Finding(
                            rule="etype-unresolvable", path=mod.rel,
                            line=node.lineno, scope="<module>",
                            message=f"etype registry key {tag!r} maps to "
                                    f"{vname or 'a non-name value'} — tag "
                                    f"and class name must match for "
                                    f"type(e).__name__ roundtripping"))
                # registry keys count as handled etype branches: the
                # dict lookup IS the dispatch
        # exhaustiveness: every emitted kind has a PEER handler branch
        peer = {"worker": "client", "client": "worker"}
        for side, kinds in emitted.items():
            for kind, (scope, line) in sorted(kinds.items()):
                if kind not in handled[peer[side]]:
                    findings.append(Finding(
                        rule="protocol-unhandled", path=mod.rel, line=line,
                        scope=scope,
                        message=f'message kind "{kind}" emitted by the '
                                f'{side} side has no handler branch on '
                                f'the {peer[side]} side'))
        for side, kinds in handled.items():
            if not emitted[peer[side]]:
                continue        # peer emits nothing literal: can't judge
            for kind, (scope, line) in sorted(kinds.items()):
                if kind not in emitted[peer[side]]:
                    findings.append(Finding(
                        rule="protocol-stale-handler", path=mod.rel,
                        line=line, scope=scope,
                        message=f'handler branch for kind "{kind}" but '
                                f'the {peer[side]} side never emits it'))
        # etype tags must resolve to module-level classes
        seen_tags: Set[str] = set()
        for tag, scope, line in etype_refs:
            if tag in seen_tags:
                continue
            seen_tags.add(tag)
            if tag not in top_names:
                findings.append(Finding(
                    rule="etype-unresolvable", path=mod.rel, line=line,
                    scope=scope,
                    message=f"etype tag {tag!r} does not name a class "
                            f"defined or imported at module top level — "
                            f"it can never roundtrip"))
        if etype_refs and not etype_emitted:
            tag, scope, line = etype_refs[0]
            findings.append(Finding(
                rule="etype-never-sent", path=mod.rel, line=line,
                scope=scope,
                message="etype tags are handled on this boundary but no "
                        "emitted message literal carries an \"etype\" "
                        "key — typed errors degrade to RuntimeError"))
    return findings


def _module_names(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            names.add(node.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                names.add((a.asname or a.name).split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                names.add(a.asname or a.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names
