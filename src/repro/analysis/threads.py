"""Pass 4 — thread hygiene.

The serving core runs four thread populations (engine stepper, worker
serve + per-request completion threads, frontend rx dispatch, router
monitor/respawn).  Debugging concurrent crashes starts with ``py-spy``
/ faulthandler output, which is useless when every thread is called
``Thread-7``; and a serve-loop thread that swallows exceptions (or dies
without signaling) turns a crash into a silent hang — the exact bug
class PR 6's typed crash propagation exists to kill.

Rules
-----
``thread-unnamed``
    ``threading.Thread(...)`` without a ``name=`` kwarg.
``thread-not-daemon-or-joined``
    Thread created neither ``daemon=True`` nor (statically detectably)
    ``.join()``-ed in the same module — an interpreter-exit hang.
``thread-target-unguarded``
    A ``target=`` function with no top-level broad ``except`` — an
    uncaught exception kills the thread with no crash signal.
``silent-except``
    A broad handler (``except``/``except Exception``/``BaseException``)
    inside a ``while`` loop or a thread-target function whose body
    neither raises nor calls anything — the failure is swallowed with
    no re-signal (crash message, ``_die``, or log).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import (Finding, Module, is_broad_handler,
                                   self_attr)


def _is_thread_ctor(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute):
        return (f.attr == "Thread" and isinstance(f.value, ast.Name)
                and f.value.id == "threading")
    return isinstance(f, ast.Name) and f.id == "Thread"


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _target_name(call: ast.Call) -> Optional[str]:
    t = _kw(call, "target")
    if t is None:
        return None
    name = self_attr(t)
    if name is not None:
        return name
    if isinstance(t, ast.Name):
        return t.id
    return None


def _has_join(tree: ast.Module) -> Set[str]:
    """Names/attrs that have ``.join()`` called on them anywhere in the
    module (thread-shaped receivers only; ``", ".join`` is a string)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "join"):
            recv = node.func.value
            name = self_attr(recv)
            if name is not None:
                out.add(name)
            elif isinstance(recv, ast.Name):
                out.add(recv.id)
    return out


def _scope_of(tree: ast.Module, node: ast.AST) -> str:
    for cls in ast.walk(tree):
        if isinstance(cls, ast.ClassDef):
            for meth in cls.body:
                if isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if node in ast.walk(meth):
                        return f"{cls.name}.{meth.name}"
    for fn in tree.body:
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node in ast.walk(fn):
                return fn.name
    return "<module>"


def _assigned_token(tree: ast.Module, call: ast.Call) -> Optional[str]:
    """If the Thread ctor result is assigned, the target's name."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and node.value is call:
            t = node.targets[0]
            name = self_attr(t)
            if name is not None:
                return name
            if isinstance(t, ast.Name):
                return t.id
        # `threading.Thread(...).start()` chains are unassigned
    return None


def _has_toplevel_broad_try(fn: ast.FunctionDef) -> bool:
    for stmt in fn.body:
        if isinstance(stmt, ast.Try):
            if any(is_broad_handler(h) for h in stmt.handlers):
                return True
    return False


class _SilentExceptVisitor(ast.NodeVisitor):
    """Broad handlers that swallow: no Raise and no Call in the body,
    inside a ``while`` loop or a thread-target function."""

    def __init__(self, mod: Module, scope_fn, targets: Set[str],
                 findings: List[Finding]):
        self.mod = mod
        self.scope_fn = scope_fn
        self.targets = targets
        self.findings = findings
        self.while_depth = 0
        self.fn_stack: List[str] = []

    def visit_While(self, node: ast.While):
        self.while_depth += 1
        self.generic_visit(node)
        self.while_depth -= 1

    def _visit_fn(self, node):
        self.fn_stack.append(node.name)
        self.generic_visit(node)
        self.fn_stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_fn

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        in_target = any(fn in self.targets for fn in self.fn_stack)
        if (is_broad_handler(node) and (self.while_depth > 0 or in_target)):
            has_signal = any(isinstance(sub, (ast.Raise, ast.Call))
                             for stmt in node.body
                             for sub in ast.walk(stmt))
            if not has_signal:
                where = ("a serve-loop" if self.while_depth > 0
                         else "a thread-target function")
                self.findings.append(Finding(
                    rule="silent-except", path=self.mod.rel,
                    line=node.lineno, scope=self.scope_fn(node),
                    message=f"broad except inside {where} swallows the "
                            f"failure without re-signaling (raise, crash "
                            f"message, or log)"))
        self.generic_visit(node)


def run(modules: Sequence[Module]) -> List[Finding]:
    findings: List[Finding] = []
    for mod in modules:
        tree = mod.tree
        joined = _has_join(tree)
        targets: Set[str] = set()
        thread_calls: List[ast.Call] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _is_thread_ctor(node):
                thread_calls.append(node)
                tname = _target_name(node)
                if tname:
                    targets.add(tname)
        # function defs by name (methods and module functions alike)
        fndefs: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fndefs.setdefault(node.name, node)

        for call in thread_calls:
            scope = _scope_of(tree, call)
            if _kw(call, "name") is None:
                findings.append(Finding(
                    rule="thread-unnamed", path=mod.rel, line=call.lineno,
                    scope=scope,
                    message="threading.Thread(...) without name= — "
                            "unnameable in stack dumps and profilers"))
            daemon = _kw(call, "daemon")
            is_daemon = (isinstance(daemon, ast.Constant)
                         and daemon.value is True)
            if not is_daemon:
                token = _assigned_token(tree, call)
                if token is None or token not in joined:
                    findings.append(Finding(
                        rule="thread-not-daemon-or-joined", path=mod.rel,
                        line=call.lineno, scope=scope,
                        message="thread is neither daemon=True nor "
                                ".join()-ed in this module — interpreter "
                                "exit will hang on it"))

        for tname in sorted(targets):
            fn = fndefs.get(tname)
            if fn is None:
                continue        # cross-module target: out of scope
            if not _has_toplevel_broad_try(fn):
                findings.append(Finding(
                    rule="thread-target-unguarded", path=mod.rel,
                    line=fn.lineno, scope=_scope_of(tree, fn),
                    message=f"thread target {tname}() has no top-level "
                            f"broad except — an uncaught exception kills "
                            f"the thread with no crash signal"))

        _SilentExceptVisitor(mod, lambda n: _scope_of(tree, n), targets,
                             findings).visit(tree)
    return findings
