"""Shared plumbing for the analysis passes: the Finding record, parsed
module handles, waiver comments, and the cross-module class registry
(including the per-class annotation conventions every pass reads).

Annotation conventions (all plain class-level literals, so they are
readable at runtime AND by ``ast.literal_eval`` here):

``_GUARDED_BY = {"_lock": ("attr", ...)}``
    Attributes of *self* that may only be read/written while
    ``with self._lock`` is held.  ``__init__`` is exempt (construction
    happens-before publication).

``_GUARDED_FIELDS = {"_lock": ("field", ...)}``
    Record fields of *owned* objects (accessed through any non-self
    receiver inside the declaring class's methods) guarded by the
    declaring class's lock — e.g. ``_Replica`` fields guarded by
    ``RouterEngine._lock``.

``_ASSUMES_HELD = {"_lock": ("method", ...)}``
    Methods whose contract is "caller holds the lock": their bodies are
    analyzed as if the lock were held, and every *call site* of them
    inside the class must itself hold the lock.

``_THREAD_CONFINED = ("attr", ...)`` / ``_CROSS_THREAD = ("method", ...)``
    Lock-free classes whose mutable state is confined to one thread
    (the engine loop).  Methods listed in ``_CROSS_THREAD`` are the
    only ones other threads may call; inside them, confined attributes
    must not be mutated and must not be iterated directly (snapshot
    with ``list(...)`` first), and only other ``_CROSS_THREAD`` methods
    of self may be called.

Waivers: a finding whose source line carries ``lint: ignore[<rule>]``
is suppressed (counted separately in the report).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

_IGNORE_RE = re.compile(r"lint:\s*ignore\[([a-z0-9_,\- ]+)\]")


@dataclass(frozen=True)
class Finding:
    """One analyzer finding.  ``key`` (the baseline identity) excludes
    the line number so baselines survive unrelated edits."""

    rule: str          # e.g. "lock-discipline"
    path: str          # repo-relative posix path
    line: int          # 1-based
    scope: str         # "Class.method" (or "<module>")
    message: str       # human detail; MUST NOT embed line numbers

    @property
    def key(self) -> str:
        return f"{self.rule}::{self.path}::{self.scope}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.scope}] {self.message}"


@dataclass
class Module:
    """A parsed source file plus the raw lines (for waiver comments)."""

    path: Path
    rel: str                      # path relative to the lint root, posix
    tree: ast.Module
    lines: List[str]

    def waived_rules(self, line: int) -> Tuple[str, ...]:
        if 1 <= line <= len(self.lines):
            m = _IGNORE_RE.search(self.lines[line - 1])
            if m:
                return tuple(r.strip() for r in m.group(1).split(","))
        return ()


def load_module(path: Path, root: Path) -> Module:
    src = path.read_text()
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    return Module(path=path, rel=rel, tree=ast.parse(src, filename=str(path)),
                  lines=src.splitlines())


# -- class registry -------------------------------------------------------

_ANNOTATIONS = ("_GUARDED_BY", "_GUARDED_FIELDS", "_ASSUMES_HELD",
                "_THREAD_CONFINED", "_CROSS_THREAD")


@dataclass
class ClassInfo:
    name: str
    module: Module
    node: ast.ClassDef
    #: annotation name -> literal value (dict/tuple), absent if undeclared
    annotations: Dict[str, object] = field(default_factory=dict)

    @property
    def guarded_by(self) -> Dict[str, Tuple[str, ...]]:
        return dict(self.annotations.get("_GUARDED_BY", {}))

    @property
    def guarded_fields(self) -> Dict[str, Tuple[str, ...]]:
        return dict(self.annotations.get("_GUARDED_FIELDS", {}))

    @property
    def assumes_held(self) -> Dict[str, Tuple[str, ...]]:
        return dict(self.annotations.get("_ASSUMES_HELD", {}))

    @property
    def thread_confined(self) -> Tuple[str, ...]:
        return tuple(self.annotations.get("_THREAD_CONFINED", ()))

    @property
    def cross_thread(self) -> Tuple[str, ...]:
        return tuple(self.annotations.get("_CROSS_THREAD", ()))

    def methods(self) -> Dict[str, ast.FunctionDef]:
        out: Dict[str, ast.FunctionDef] = {}
        for stmt in self.node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out[stmt.name] = stmt
        return out


def build_class_map(modules: Sequence[Module]) -> Dict[str, ClassInfo]:
    """All top-level classes across the analyzed modules, keyed by class
    name (the serving core has no duplicate class names; on collision
    the first module wins, matching the hierarchy config's intent)."""
    out: Dict[str, ClassInfo] = {}
    for mod in modules:
        for node in mod.tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            info = ClassInfo(name=node.name, module=mod, node=node)
            for stmt in node.body:
                if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                        and isinstance(stmt.targets[0], ast.Name)
                        and stmt.targets[0].id in _ANNOTATIONS):
                    try:
                        info.annotations[stmt.targets[0].id] = \
                            ast.literal_eval(stmt.value)
                    except ValueError:
                        pass         # non-literal registry: ignored
            out.setdefault(node.name, info)
    return out


# -- small AST helpers used by several passes -----------------------------

def self_attr(node: ast.AST) -> Optional[str]:
    """``self.X`` -> ``"X"`` (else None)."""
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def flatten_targets(target: ast.AST) -> List[ast.AST]:
    """Assignment target tree -> flat list of leaf targets."""
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[ast.AST] = []
        for elt in target.elts:
            out.extend(flatten_targets(elt))
        return out
    if isinstance(target, ast.Starred):
        return flatten_targets(target.value)
    return [target]


def is_broad_handler(handler: ast.ExceptHandler) -> bool:
    """bare ``except:``, ``except Exception``, ``except BaseException``,
    or a tuple containing either."""
    t = handler.type
    if t is None:
        return True
    names = []
    for node in ([t.elts if isinstance(t, ast.Tuple) else [t]][0]):
        if isinstance(node, ast.Name):
            names.append(node.id)
        elif isinstance(node, ast.Attribute):
            names.append(node.attr)
    return any(n in ("Exception", "BaseException") for n in names)
