"""repro.analysis — stdlib-ast static analysis for the threaded serving core.

Four passes over ``src/repro/core`` + ``src/repro/kernels`` (run as
``python -m repro.analysis.lint``):

* :mod:`repro.analysis.locks`    — GUARDED_BY lock discipline + the
  declared lock-acquisition hierarchy (deadlock reports).
* :mod:`repro.analysis.donation` — use-after-donate of buffers passed to
  ``jax.jit(..., donate_argnums=...)`` call sites.
* :mod:`repro.analysis.protocol` — worker JSON-boundary exhaustiveness:
  every emitted ``{"kind": ...}`` literal has a peer handler branch and
  every typed-error ``etype`` tag roundtrips.
* :mod:`repro.analysis.threads`  — thread hygiene: named +
  daemon-or-joined threads, guarded thread targets, no silent broad
  ``except`` in serve loops.

Plus a docs cross-check (:mod:`repro.analysis.docs_check`) that keeps
``docs/ARCHITECTURE.md``'s threading section consistent with the
annotations, and a findings baseline gate used by
``scripts/check_tree.sh``.

The analyzer is purely syntactic: analyzed files are parsed, never
imported, so corpus snippets and half-broken trees lint fine.
"""
