"""Pass 1 — lock discipline + the declared lock-acquisition hierarchy.

Rules
-----
``lock-discipline``
    A ``_GUARDED_BY`` attribute of self is read/written outside
    ``with self.<lock>`` (``__init__`` exempt), or a ``_GUARDED_FIELDS``
    record field is touched through a non-self receiver outside the
    declaring class's lock.
``assumes-held``
    A method declared in ``_ASSUMES_HELD`` ("caller holds the lock") is
    called from a context that does not hold the lock.
``lock-order``
    A code path acquires a lock that precedes an already-held lock in
    :data:`repro.analysis.hierarchy.LOCK_ORDER`, or (re-)acquires a
    non-reentrant lock it already holds — directly via nested ``with``,
    or transitively through a resolvable call chain.
``cross-thread-mutation`` / ``unsnapshotted-iteration`` /
``cross-thread-call``
    A ``_CROSS_THREAD`` method of a lock-free (thread-confined) class
    mutates confined state, iterates a confined collection without
    snapshotting (``list(...)`` first), or calls a self-method that is
    not itself declared cross-thread-safe.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import hierarchy
from repro.analysis.common import (ClassInfo, Finding, Module,
                                   build_class_map, self_attr)

_MUTATORS = {"append", "appendleft", "add", "insert", "extend", "update",
             "pop", "popleft", "popitem", "remove", "discard", "clear",
             "setdefault"}


@dataclass
class LocksConfig:
    lock_order: Tuple[str, ...] = hierarchy.LOCK_ORDER
    attr_types: Dict[str, str] = field(
        default_factory=lambda: dict(hierarchy.ATTR_TYPES))


@dataclass
class _CallSite:
    callee: Tuple[str, str]          # (class, method)
    line: int
    held: Tuple[str, ...]            # lock ids held at the call
    scope: str                       # caller "Class.method"
    rel: str                         # caller module path


class _MethodScanner(ast.NodeVisitor):
    """One pass over one method body: guarded-attribute checks with a
    held-lock stack, direct ``with``-acquire ordering, confined-state
    rules, and collection of call sites + direct acquires for the
    transitive hierarchy phase."""

    def __init__(self, cls: ClassInfo, meth: ast.FunctionDef,
                 cfg: LocksConfig, findings: List[Finding]):
        self.cls = cls
        self.meth = meth
        self.cfg = cfg
        self.findings = findings
        self.scope = f"{cls.name}.{meth.name}"
        self.rel = cls.module.rel
        self.is_init = meth.name == "__init__"
        self.held: List[str] = []
        self.calls: List[_CallSite] = []
        self.acquires: Set[str] = set()
        # lock name -> guarded self attrs / guarded foreign fields
        self.guarded = {k: set(v) for k, v in cls.guarded_by.items()}
        self.fields = {k: set(v) for k, v in cls.guarded_fields.items()}
        # method -> locks it assumes held
        self.assumed: Dict[str, Set[str]] = {}
        for lock, meths in cls.assumes_held.items():
            for m in meths:
                self.assumed.setdefault(m, set()).add(lock)
        for lock in self.assumed.get(meth.name, ()):
            self.held.append(self._lock_id(lock))
        self.cross = meth.name in cls.cross_thread
        self.confined = set(cls.thread_confined)
        self._seen: Set[Tuple[str, str, int]] = set()

    # -- helpers ---------------------------------------------------------
    def _lock_id(self, lock_attr: str) -> str:
        return f"{self.cls.name}.{lock_attr}"

    def _emit(self, rule: str, line: int, message: str):
        key = (rule, message, line)
        if key not in self._seen:
            self._seen.add(key)
            self.findings.append(Finding(rule=rule, path=self.rel,
                                         line=line, scope=self.scope,
                                         message=message))

    def _is_lock_attr(self, name: str) -> bool:
        return (name in self.guarded or name in self.fields
                or name in self.cls.assumes_held or "lock" in name)

    def _check_acquire(self, lock_id: str, line: int):
        if lock_id in self.held:
            self._emit("lock-order", line,
                       f"re-acquires non-reentrant {lock_id} already "
                       f"held on this path (self-deadlock)")
            return
        order = self.cfg.lock_order
        if lock_id in order:
            for h in self.held:
                if h in order and order.index(h) > order.index(lock_id):
                    self._emit(
                        "lock-order", line,
                        f"acquires {lock_id} while holding {h} — "
                        f"violates declared order "
                        f"{' -> '.join(order)}")

    def scan(self):
        for stmt in self.meth.body:
            self.visit(stmt)

    # -- lock regions ----------------------------------------------------
    def visit_With(self, node: ast.With):
        acquired: List[str] = []
        for item in node.items:
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
            name = self_attr(item.context_expr)
            if name is not None and self._is_lock_attr(name):
                lock_id = self._lock_id(name)
                self._check_acquire(lock_id, node.lineno)
                self.acquires.add(lock_id)
                acquired.append(lock_id)
        self.held.extend(acquired)
        for stmt in node.body:
            self.visit(stmt)
        for _ in acquired:
            self.held.pop()

    # -- guarded attribute accesses --------------------------------------
    def visit_Attribute(self, node: ast.Attribute):
        name = self_attr(node)
        if name is not None:
            if not self.is_init:
                for lock, attrs in self.guarded.items():
                    if name in attrs and self._lock_id(lock) not in self.held:
                        ctx = ("write" if isinstance(node.ctx,
                                                     (ast.Store, ast.Del))
                               else "read")
                        self._emit("lock-discipline", node.lineno,
                                   f"{ctx} of self.{name} (guarded by "
                                   f"self.{lock}) without the lock held")
        elif not self.is_init:
            # record fields of owned objects (non-self receiver)
            for lock, fields in self.fields.items():
                if node.attr in fields and self._lock_id(lock) not in self.held:
                    ctx = ("write" if isinstance(node.ctx,
                                                 (ast.Store, ast.Del))
                           else "read")
                    self._emit("lock-discipline", node.lineno,
                               f"{ctx} of guarded record field "
                               f".{node.attr} (guarded by self.{lock}) "
                               f"without the lock held")
        self.generic_visit(node)

    # -- confined-state rules (cross-thread methods only) ----------------
    def _confined_target(self, node: ast.AST) -> Optional[str]:
        name = self_attr(node)
        if name is not None and name in self.confined:
            return name
        # self.X[...] = ... mutates self.X as well
        if isinstance(node, ast.Subscript):
            return self._confined_target(node.value)
        return None

    def visit_Assign(self, node: ast.Assign):
        if self.cross:
            for t in node.targets:
                name = self._confined_target(t)
                if name is not None:
                    self._emit("cross-thread-mutation", node.lineno,
                               f"cross-thread method mutates "
                               f"thread-confined self.{name}")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        if self.cross:
            name = self._confined_target(node.target)
            if name is not None:
                self._emit("cross-thread-mutation", node.lineno,
                           f"cross-thread method mutates "
                           f"thread-confined self.{name}")
        self.generic_visit(node)

    def _check_iter(self, it: ast.AST, line: int):
        name = self_attr(it)
        if name is not None and name in self.confined:
            self._emit("unsnapshotted-iteration", line,
                       f"cross-thread method iterates thread-confined "
                       f"self.{name} directly — snapshot with "
                       f"list(self.{name}) first")

    def visit_For(self, node: ast.For):
        if self.cross:
            self._check_iter(node.iter, node.lineno)
        self.generic_visit(node)

    def _visit_comp(self, node):
        if self.cross:
            for gen in node.generators:
                self._check_iter(gen.iter, node.lineno)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    # -- calls -----------------------------------------------------------
    def _resolve_receiver(self, func: ast.Attribute) -> Optional[str]:
        v = func.value
        if isinstance(v, ast.Name):
            if v.id == "self":
                return self.cls.name
            return self.cfg.attr_types.get(v.id)
        if isinstance(v, ast.Attribute):
            return self.cfg.attr_types.get(v.attr)
        return None

    def visit_Call(self, node: ast.Call):
        func = node.func
        if isinstance(func, ast.Attribute):
            recv = self._resolve_receiver(func)
            if recv is not None:
                if recv == self.cls.name:
                    # assumes-held contract at the call site
                    for lock in self.assumed.get(func.attr, ()):
                        if self._lock_id(lock) not in self.held:
                            self._emit(
                                "assumes-held", node.lineno,
                                f"calls self.{func.attr}() which assumes "
                                f"self.{lock} is held, without the lock")
                    if (self.cross and func.attr in self.cls.methods()
                            and func.attr not in self.cls.cross_thread):
                        self._emit(
                            "cross-thread-call", node.lineno,
                            f"cross-thread method calls self."
                            f"{func.attr}() which is not declared "
                            f"cross-thread-safe")
                self.calls.append(_CallSite(
                    callee=(recv, func.attr), line=node.lineno,
                    held=tuple(self.held), scope=self.scope, rel=self.rel))
            # mutating calls on confined collections
            if self.cross:
                name = self_attr(func.value)
                if (name is not None and name in self.confined
                        and func.attr in _MUTATORS):
                    self._emit("cross-thread-mutation", node.lineno,
                               f"cross-thread method mutates "
                               f"thread-confined self.{name} "
                               f"(.{func.attr}())")
        self.generic_visit(node)


def run(modules: Sequence[Module],
        config: Optional[LocksConfig] = None) -> List[Finding]:
    cfg = config or LocksConfig()
    classes = build_class_map(modules)
    findings: List[Finding] = []
    direct: Dict[Tuple[str, str], Set[str]] = {}
    calls: List[_CallSite] = []
    defined: Set[Tuple[str, str]] = set()

    for cls in classes.values():
        for name, meth in cls.methods().items():
            defined.add((cls.name, name))
            sc = _MethodScanner(cls, meth, cfg, findings)
            sc.scan()
            direct[(cls.name, name)] = sc.acquires
            calls.extend(sc.calls)

    # transitive closure: which locks can a (class, method) acquire?
    acq: Dict[Tuple[str, str], Set[str]] = {k: set(v)
                                            for k, v in direct.items()}
    edges: Dict[Tuple[str, str], Set[Tuple[str, str]]] = {}
    for c in calls:
        caller = None
        for key in defined:
            if f"{key[0]}.{key[1]}" == c.scope:
                caller = key
                break
        if caller is not None and c.callee in defined:
            edges.setdefault(caller, set()).add(c.callee)
    changed = True
    while changed:
        changed = False
        for caller, callees in edges.items():
            for callee in callees:
                extra = acq.get(callee, set()) - acq.setdefault(caller, set())
                if extra:
                    acq[caller].update(extra)
                    changed = True

    # deadlock reports at call sites made while holding locks
    order = cfg.lock_order
    seen: Set[Tuple[str, int, str]] = set()
    for c in calls:
        if not c.held or c.callee not in defined:
            continue
        for lock in sorted(acq.get(c.callee, ())):
            msg = None
            if lock in c.held:
                msg = (f"calls {c.callee[0]}.{c.callee[1]}() which may "
                       f"re-acquire already-held {lock} (deadlock)")
            elif lock in order:
                for h in c.held:
                    if h in order and order.index(h) > order.index(lock):
                        msg = (f"calls {c.callee[0]}.{c.callee[1]}() "
                               f"which may acquire {lock} while holding "
                               f"{h} — violates declared order "
                               f"{' -> '.join(order)}")
                        break
            if msg and (c.rel, c.line, msg) not in seen:
                seen.add((c.rel, c.line, msg))
                findings.append(Finding(rule="lock-order", path=c.rel,
                                        line=c.line, scope=c.scope,
                                        message=msg))
    return findings
