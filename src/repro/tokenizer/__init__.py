from repro.tokenizer.bpe import ByteBPETokenizer  # noqa: F401
from repro.tokenizer.streamer import DetokStreamer  # noqa: F401
