"""Byte-level BPE tokenizer (trainable, offline-friendly).

WebLLM ships each model's tokenizer alongside the compiled artifact; we
train small byte-level BPE vocabularies on sample text.  Byte fallback is
total: every byte is a base token, so encode/decode round-trips arbitrary
UTF-8 (property-tested).  ``token_bytes`` exposes the raw byte sequence
per id — the grammar engine builds its trie from that.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SPECIALS = ("<|pad|>", "<|bos|>", "<|eos|>", "<|im_start|>", "<|im_end|>")


class ByteBPETokenizer:
    def __init__(self, merges: Optional[List[Tuple[int, int]]] = None,
                 specials: Sequence[str] = SPECIALS):
        self.specials = list(specials)
        self.n_special = len(self.specials)
        self.merges: List[Tuple[int, int]] = list(merges or [])
        self._rebuild()

    # -- identity ------------------------------------------------------
    @property
    def pad_id(self) -> int:
        return 0

    @property
    def bos_id(self) -> int:
        return 1

    @property
    def eos_id(self) -> int:
        return 2

    @property
    def vocab_size(self) -> int:
        return self.n_special + 256 + len(self.merges)

    def _rebuild(self):
        # token id layout: [specials][256 bytes][merges]
        self._bytes_of: List[bytes] = [s.encode() for s in self.specials]
        self._bytes_of += [bytes([b]) for b in range(256)]
        self._merge_rank: Dict[Tuple[int, int], int] = {}
        for rank, (a, b) in enumerate(self.merges):
            self._bytes_of.append(self._bytes_of[a] + self._bytes_of[b])
            self._merge_rank[(a, b)] = rank
        self._special_ids = {s: i for i, s in enumerate(self.specials)}

    # -- training ------------------------------------------------------
    @classmethod
    def train(cls, corpus: Iterable[str], vocab_size: int = 1024,
              specials: Sequence[str] = SPECIALS) -> "ByteBPETokenizer":
        tok = cls(specials=specials)
        n_merges = max(0, vocab_size - tok.vocab_size)
        words: Counter = Counter()
        for text in corpus:
            for piece in text.split(" "):
                words[(piece + " ").encode()] += 1
        seqs = {w: [tok.n_special + b for b in w] for w in words}
        for _ in range(n_merges):
            pairs: Counter = Counter()
            for w, cnt in words.items():
                s = seqs[w]
                for i in range(len(s) - 1):
                    pairs[(s[i], s[i + 1])] += cnt
            if not pairs:
                break
            (a, b), cnt = pairs.most_common(1)[0]
            if cnt < 2:
                break
            new_id = tok.vocab_size
            tok.merges.append((a, b))
            tok._rebuild()
            for w in seqs:
                s = seqs[w]
                out = []
                i = 0
                while i < len(s):
                    if i + 1 < len(s) and s[i] == a and s[i + 1] == b:
                        out.append(new_id)
                        i += 2
                    else:
                        out.append(s[i])
                        i += 1
                seqs[w] = out
        return tok

    # -- encode / decode ----------------------------------------------
    def encode(self, text: str, *, add_bos: bool = False,
               allow_specials: bool = True) -> List[int]:
        ids: List[int] = [self.bos_id] if add_bos else []
        chunks = [text]
        if allow_specials:
            chunks = self._split_specials(text)
        for chunk in chunks:
            if allow_specials and chunk in self._special_ids:
                ids.append(self._special_ids[chunk])
                continue
            ids.extend(self._encode_bytes(chunk.encode()))
        return ids

    def _split_specials(self, text: str) -> List[str]:
        out, rest = [], text
        while rest:
            hits = [(rest.find(s), s) for s in self.specials
                    if rest.find(s) >= 0]
            if not hits:
                out.append(rest)
                break
            pos, s = min(hits)
            if pos:
                out.append(rest[:pos])
            out.append(s)
            rest = rest[pos + len(s):]
        return out

    def _encode_bytes(self, data: bytes) -> List[int]:
        s = [self.n_special + b for b in data]
        while len(s) > 1:
            best_rank, best_i = None, -1
            for i in range(len(s) - 1):
                r = self._merge_rank.get((s[i], s[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_i = r, i
            if best_rank is None:
                break
            a, b = self.merges[best_rank]
            merged = self.n_special + 256 + best_rank
            s = s[:best_i] + [merged] + s[best_i + 2:]
        return s

    def token_bytes(self, token_id: int) -> bytes:
        return self._bytes_of[token_id]

    def decode(self, ids: Sequence[int]) -> str:
        data = b"".join(self._bytes_of[i] for i in ids
                        if i >= self.n_special)
        return data.decode("utf-8", errors="replace")

    # -- chat template (WebLLM-style OpenAI messages -> prompt) ---------
    def apply_chat_template(self, messages: Sequence[dict]) -> str:
        parts = []
        for m in messages:
            content = m.get("content")
            if content is None:
                # assistant tool-call turns carry no text; render the
                # calls as JSON so the model sees its own actions
                calls = []
                for c in m.get("tool_calls") or []:
                    fn = (c.function if hasattr(c, "function")
                          else (c or {}).get("function", {}))
                    calls.append({
                        "name": getattr(fn, "name", None)
                        if not isinstance(fn, dict) else fn.get("name"),
                        "arguments": getattr(fn, "arguments", None)
                        if not isinstance(fn, dict) else fn.get("arguments"),
                    })
                content = json.dumps(calls) if calls else ""
            parts.append(f"<|im_start|>{m['role']}\n{content}<|im_end|>")
        parts.append("<|im_start|>assistant\n")
        return "".join(parts)

    # -- persistence ----------------------------------------------------
    def save(self, path: str):
        Path(path).write_text(json.dumps(
            {"merges": self.merges, "specials": self.specials}))

    @classmethod
    def load(cls, path: str) -> "ByteBPETokenizer":
        d = json.loads(Path(path).read_text())
        return cls(merges=[tuple(m) for m in d["merges"]],
                   specials=d["specials"])
