"""Streaming detokenizer: emits the longest valid UTF-8 prefix as tokens
arrive.  Incomplete multi-byte codepoints split across tokens stay
buffered; permanently-invalid bytes are emitted as replacement chars
immediately (they can never be repaired by future bytes, and holding
them would starve streaming of progress chunks forever)."""
from __future__ import annotations

import codecs


class DetokStreamer:
    def __init__(self, tokenizer):
        self.tok = tokenizer
        self._dec = codecs.getincrementaldecoder("utf-8")("replace")

    def put(self, token_id: int) -> str:
        if token_id < self.tok.n_special:
            return ""                      # specials never stream out
        return self._dec.decode(self.tok.token_bytes(token_id))

    def flush(self) -> str:
        return self._dec.decode(b"", final=True)
