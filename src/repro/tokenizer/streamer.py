"""Streaming detokenizer: emits the longest valid UTF-8 prefix as tokens
arrive (multi-byte codepoints split across tokens stay buffered)."""
from __future__ import annotations

from typing import List, Optional


class DetokStreamer:
    def __init__(self, tokenizer):
        self.tok = tokenizer
        self.buf = b""

    def put(self, token_id: int) -> str:
        if token_id < self.tok.n_special:
            return ""                      # specials never stream out
        self.buf += self.tok.token_bytes(token_id)
        return self._drain()

    def _drain(self) -> str:
        # find the longest prefix that decodes cleanly
        for cut in range(len(self.buf), max(len(self.buf) - 4, -1), -1):
            try:
                text = self.buf[:cut].decode("utf-8")
            except UnicodeDecodeError:
                continue
            self.buf = self.buf[cut:]
            return text
        return ""

    def flush(self) -> str:
        text = self.buf.decode("utf-8", errors="replace")
        self.buf = b""
        return text
