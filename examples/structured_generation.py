"""Structured generation (WebLLM feature): constrain decoding with a JSON
schema and with a custom GBNF grammar — outputs are valid by construction.

    PYTHONPATH=src python examples/structured_generation.py
"""
import json

from repro.configs import get_config
from repro.core import ChatCompletionRequest, ChatMessage, MLCEngine


def main():
    engine = MLCEngine()
    engine.load_model("m", get_config("phi-3.5-mini", reduced=True),
                      max_slots=2, max_context=192)

    print("=== JSON-schema constrained ===")
    schema = {"type": "object",
              "properties": {"name": {"type": "string"},
                             "score": {"type": "integer"},
                             "valid": {"type": "boolean"}},
              "required": ["name", "score", "valid"]}
    resp = engine.chat_completions_create(ChatCompletionRequest(
        messages=[ChatMessage("user", "Describe a player as JSON.")],
        model="m", max_tokens=160, temperature=0.9, seed=5,
        response_format={"type": "json_schema", "json_schema": schema}))
    text = resp.choices[0].message.content
    print(text)
    if resp.choices[0].finish_reason == "stop":
        obj = json.loads(text)
        assert set(obj) >= {"name", "score", "valid"}
        print("-> parsed:", obj)

    print("=== custom GBNF grammar ===")
    gbnf = 'root ::= "answer: " ("yes" | "no" | "maybe") " (" [0-9] [0-9]? "% sure)"'
    resp = engine.chat_completions_create(ChatCompletionRequest(
        messages=[ChatMessage("user", "Will it rain?")],
        model="m", max_tokens=32, temperature=1.0, seed=3,
        response_format={"type": "grammar", "grammar": gbnf}))
    print(resp.choices[0].message.content,
          f"[{resp.choices[0].finish_reason}]")
    engine.shutdown()


if __name__ == "__main__":
    main()
