"""Train a tiny model on the synthetic corpus until the loss visibly
drops, then serve the trained weights through the engine.

    PYTHONPATH=src python examples/train_tiny.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ChatCompletionRequest, ChatMessage, MLCEngine
from repro.data import LMDataPipeline, synthetic_corpus
from repro.models import model
from repro.optim import adamw_init, adamw_update
from repro.tokenizer import ByteBPETokenizer


def main():
    cfg = get_config("llama-3.1-8b", reduced=True)
    docs = synthetic_corpus(300, seed=0)
    tok = ByteBPETokenizer.train(docs[:150], vocab_size=cfg.vocab_size)
    pipe = LMDataPipeline(tok, docs, seq_len=64, batch_size=8)

    params = model.init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch))(params)
        params, opt = adamw_update(grads, opt, params, lr=3e-3)
        return loss, params, opt

    it = iter(pipe)
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        loss, params, opt = step(params, opt, batch)
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(loss):.3f}")

    print("\nserving the trained weights:")
    engine = MLCEngine()
    engine.load_model("trained", cfg, params=params, tokenizer=tok,
                      max_slots=2, max_context=128)
    resp = engine.chat_completions_create(ChatCompletionRequest(
        messages=[ChatMessage("user", "the quick brown")],
        model="trained", max_tokens=16, temperature=0.5, seed=0))
    print(repr(resp.choices[0].message.content))
    engine.shutdown()


if __name__ == "__main__":
    main()
