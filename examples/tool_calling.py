"""Tool calling (WebLLM agentic scenario): the OpenAI agent loop.

Declare ``tools``, force a call with ``tool_choice="required"`` (the
function's JSON schema is compiled into the grammar engine, so the call
is well-formed by construction), execute it, feed the result back as a
``role="tool"`` message, and let the model answer.

    PYTHONPATH=src python examples/tool_calling.py
"""
import json
from dataclasses import asdict

from repro.configs import get_config
from repro.core import MLCEngine

TOOLS = [{
    "type": "function",
    "function": {
        "name": "get_weather",
        "description": "Current weather for a city",
        "parameters": {
            "type": "object",
            "properties": {"city": {"enum": ["paris", "tokyo"]}},
            "required": ["city"],
        },
    },
}]


def get_weather(city: str) -> dict:
    return {"city": city, "temp_c": 19, "sky": "clear"}


def main():
    engine = MLCEngine()
    engine.load_model("m", get_config("phi-3.5-mini", reduced=True),
                      max_slots=2, max_context=256)

    messages = [{"role": "user", "content": "What is the weather in paris?"}]
    resp = engine.chat_completions_create({
        "messages": messages, "model": "m", "max_tokens": 160,
        "temperature": 0.8, "seed": 9,
        "tools": TOOLS, "tool_choice": "required"})
    choice = resp.choices[0]
    print("finish_reason:", choice.finish_reason)
    assert choice.finish_reason == "tool_calls", choice.finish_reason

    call = choice.message.tool_calls[0]
    args = json.loads(call.function.arguments)
    print("tool call:", call.function.name, args)
    result = get_weather(**args)
    print("tool result:", result)

    # agent loop turn 2: echo the call + result, let the model answer
    messages.append({"role": "assistant", "content": None,
                     "tool_calls": [asdict(call)]})
    messages.append({"role": "tool", "tool_call_id": call.id,
                     "content": json.dumps(result)})
    final = engine.chat_completions_create({
        "messages": messages, "model": "m", "max_tokens": 24,
        "temperature": 0.8, "seed": 10,
        "tools": TOOLS, "tool_choice": "none"})
    print("assistant:", final.choices[0].message.content)
    engine.shutdown()


if __name__ == "__main__":
    main()
