"""End-to-end serving driver (the paper's deployment shape): a worker-
isolated engine serving concurrent batched requests — including a VLM
with stub image embeddings and a second model in the same engine (the
multi-model / RAG pattern) — with engine-level throughput reporting.

    PYTHONPATH=src python examples/serve_batch.py
"""
import threading
import time

import numpy as np

from repro.configs import get_config
from repro.core import (ChatCompletionRequest, ChatMessage, MLCEngine,
                        ServiceWorkerMLCEngine)


def main():
    backend = MLCEngine()
    backend.load_model("chat", get_config("yi-6b", reduced=True),
                       max_slots=4, max_context=160, quantize=True)
    vlm_cfg = get_config("internvl2-1b", reduced=True)
    backend.load_model("vlm", vlm_cfg, max_slots=2, max_context=128)
    backend.register_image(
        "vlm", "cat.png",
        np.random.default_rng(0).normal(
            size=(vlm_cfg.frontend.num_embeds, vlm_cfg.d_model))
        .astype(np.float32) * 0.02)

    # frontend handle: everything below crosses a JSON message boundary
    engine = ServiceWorkerMLCEngine(backend)

    requests = [ChatCompletionRequest(
        messages=[ChatMessage("user", f"batched request {i}")],
        model="chat", max_tokens=20, seed=i, stream=True)
        for i in range(8)]
    requests.append(ChatCompletionRequest(
        messages=[ChatMessage("user", "what is in this image?")],
        model="vlm", max_tokens=12, seed=99, image_embeds="cat.png"))

    stats = []
    lock = threading.Lock()

    def run(req):
        usage = None
        for chunk in engine.chat_completions_create(req):
            if chunk.usage:
                usage = chunk.usage
        if usage is None:   # non-stream fallback
            pass
        with lock:
            stats.append((req.model, usage))

    t0 = time.time()
    threads = [threading.Thread(target=run, args=(r,)) for r in requests]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.time() - t0

    total = sum(u.completion_tokens for _, u in stats if u)
    print(f"\nserved {len(requests)} requests ({total} tokens) "
          f"across 2 models in {wall:.2f}s -> {total/wall:.1f} tok/s")
    for m, u in stats:
        print(f"  [{m}] {u.completion_tokens} toks, "
              f"decode {u.extra['decode_tokens_per_s']} tok/s")
    engine.shutdown()


if __name__ == "__main__":
    main()
