"""Quickstart: load a model into the engine, stream a chat completion.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.core import ChatCompletionRequest, ChatMessage, MLCEngine


def main():
    engine = MLCEngine()
    # reduced llama-3.1-8b family config (random weights, tiny tokenizer —
    # the engine mechanics are identical to serving real weights)
    engine.load_model("llama", get_config("llama-3.1-8b", reduced=True),
                      max_slots=2, max_context=160)

    print("=== streaming ===")
    request = ChatCompletionRequest(
        messages=[ChatMessage("user", "Tell me something.")],
        model="llama", max_tokens=24, temperature=0.8, seed=0, stream=True)
    for chunk in engine.chat_completions_create(request):
        delta = chunk.choices[0].delta.content
        if delta:
            print(delta, end="", flush=True)
        if chunk.usage:
            print(f"\n--- usage: {chunk.usage.completion_tokens} tokens, "
                  f"{chunk.usage.extra['decode_tokens_per_s']} tok/s")

    print("=== non-streaming ===")
    response = engine.chat_completions_create(ChatCompletionRequest(
        messages=[ChatMessage("user", "And again, all at once.")],
        model="llama", max_tokens=16, seed=1))
    print(repr(response.choices[0].message.content))
    print("finish_reason:", response.choices[0].finish_reason)
    engine.shutdown()


if __name__ == "__main__":
    main()
