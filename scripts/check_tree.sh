#!/usr/bin/env bash
# Tree hygiene gate (tier-1): no tracked bytecode, and src compiles.
set -euo pipefail
cd "$(dirname "$0")/.."

bad=$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$' || true)
if [ -n "$bad" ]; then
    echo "ERROR: tracked bytecode files:" >&2
    echo "$bad" >&2
    exit 1
fi

python -m compileall -q src
echo "check_tree: OK"
