#!/usr/bin/env bash
# Tree hygiene gate (tier-1): no tracked bytecode, src compiles, and the
# user-facing docs exist with file references that resolve.
set -euo pipefail
cd "$(dirname "$0")/.."

bad=$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$' || true)
if [ -n "$bad" ]; then
    echo "ERROR: tracked bytecode files:" >&2
    echo "$bad" >&2
    exit 1
fi

python -m compileall -q src

# docs gate: first-class docs must exist ...
for doc in README.md docs/ARCHITECTURE.md; do
    if [ ! -f "$doc" ]; then
        echo "ERROR: missing $doc" >&2
        exit 1
    fi
done
# ... and every repo-relative file reference inside them must resolve
# (paths containing a directory separator, e.g. src/repro/core/engine.py,
# benchmarks/run.py — bare names like ops.py are not checked, and URLs
# are stripped first so external links never trip the gate)
missing=0
for doc in README.md docs/ARCHITECTURE.md; do
    while IFS= read -r ref; do
        if [ ! -e "$ref" ]; then
            echo "ERROR: $doc references missing path: $ref" >&2
            missing=1
        fi
    done < <(sed -E 's#[a-z]+://[^ )>]*##g' "$doc" \
             | grep -oE '[A-Za-z0-9_.-]+(/[A-Za-z0-9_.-]+)+\.(py|sh|md|json)' \
             | sort -u)
done
if [ "$missing" -ne 0 ]; then
    exit 1
fi

# static-analysis gate: the four concurrency passes over the serving
# core (lock discipline, donation safety, protocol exhaustiveness,
# thread hygiene) plus the docs cross-check.  Only findings NOT in the
# committed baseline fail — introducing a new one breaks the build.
PYTHONPATH=src python -m repro.analysis.lint --baseline

echo "check_tree: OK"
